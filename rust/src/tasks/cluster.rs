//! Average-linkage agglomerative clustering with a similarity threshold —
//! the clustering step of Cattan et al. 2020 used for cross-document
//! coreference (Sec. 4.3). Lance-Williams updates on a dense similarity
//! matrix; merging stops when the best pair falls below the threshold.

use crate::linalg::Mat;

/// Cluster `sim` (n x n similarity matrix, symmetric) with average
/// linkage; stop when max inter-cluster similarity < `threshold`.
/// Returns cluster id per point.
pub fn average_linkage(sim: &Mat, threshold: f64) -> Vec<usize> {
    let n = sim.rows;
    assert!(sim.is_square());
    // Active cluster -> member count; merged clusters become inactive.
    let mut active: Vec<bool> = vec![true; n];
    let mut size: Vec<f64> = vec![1.0; n];
    let mut s = sim.clone(); // inter-cluster average similarity
    let mut parent: Vec<usize> = (0..n).collect();

    loop {
        // Find best active pair.
        let mut best = (f64::NEG_INFINITY, 0, 0);
        for i in 0..n {
            if !active[i] {
                continue;
            }
            for j in (i + 1)..n {
                if !active[j] {
                    continue;
                }
                let v = s.get(i, j);
                if v > best.0 {
                    best = (v, i, j);
                }
            }
        }
        let (v, a, b) = best;
        if v < threshold || !v.is_finite() {
            break;
        }
        // Merge b into a with Lance-Williams average-linkage update.
        let (na, nb) = (size[a], size[b]);
        for k in 0..n {
            if !active[k] || k == a || k == b {
                continue;
            }
            let new = (na * s.get(a, k) + nb * s.get(b, k)) / (na + nb);
            s.set(a, k, new);
            s.set(k, a, new);
        }
        size[a] += size[b];
        active[b] = false;
        parent[b] = a;
    }
    // Path-compress to cluster representatives, then densify ids.
    let mut root = vec![0usize; n];
    for i in 0..n {
        let mut r = i;
        while parent[r] != r {
            r = parent[r];
        }
        root[i] = r;
    }
    let mut remap = std::collections::HashMap::new();
    let mut next = 0usize;
    root.iter()
        .map(|&r| {
            *remap.entry(r).or_insert_with(|| {
                let id = next;
                next += 1;
                id
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn block_sim(blocks: &[usize], within: f64, across: f64, noise: f64, rng: &mut Rng) -> Mat {
        let n = blocks.len();
        let mut m = Mat::from_fn(n, n, |i, j| {
            let base = if blocks[i] == blocks[j] { within } else { across };
            base + noise * rng.normal()
        });
        for i in 0..n {
            m.set(i, i, 1.0);
        }
        m.symmetrized()
    }

    #[test]
    fn recovers_planted_blocks() {
        let mut rng = Rng::new(1);
        let blocks: Vec<usize> = (0..30).map(|i| i / 10).collect();
        let sim = block_sim(&blocks, 0.8, 0.1, 0.03, &mut rng);
        let got = average_linkage(&sim, 0.45);
        // Same block -> same cluster; different blocks -> different.
        for i in 0..30 {
            for j in 0..30 {
                assert_eq!(
                    got[i] == got[j],
                    blocks[i] == blocks[j],
                    "pair ({i},{j})"
                );
            }
        }
    }

    #[test]
    fn high_threshold_yields_singletons() {
        let mut rng = Rng::new(2);
        let blocks: Vec<usize> = (0..12).map(|i| i / 4).collect();
        let sim = block_sim(&blocks, 0.6, 0.1, 0.01, &mut rng);
        let got = average_linkage(&sim, 10.0);
        let distinct: std::collections::HashSet<usize> = got.iter().copied().collect();
        assert_eq!(distinct.len(), 12);
    }

    #[test]
    fn low_threshold_merges_everything() {
        let mut rng = Rng::new(3);
        let blocks: Vec<usize> = (0..12).map(|i| i / 4).collect();
        let sim = block_sim(&blocks, 0.6, 0.1, 0.01, &mut rng);
        let got = average_linkage(&sim, -10.0);
        assert!(got.iter().all(|&c| c == got[0]));
    }
}
