//! Clustering for the downstream tasks and the serving index:
//!
//! * [`average_linkage`] — agglomerative clustering with a similarity
//!   threshold, the coreference step of Cattan et al. 2020 (Sec. 4.3).
//!   Lance-Williams updates on a dense similarity matrix.
//! * [`kmeans`] — Lloyd's algorithm with k-means++ seeding over row
//!   points, the coarse quantizer of the top-k retrieval index
//!   (`index::ivf`). The assignment step is sharded on the pool workers
//!   (points are independent, so results are bit-identical for every
//!   worker count).

use crate::linalg::Mat;
use crate::util::pool;
use crate::util::rng::Rng;

/// Cluster `sim` (n x n similarity matrix, symmetric) with average
/// linkage; stop when max inter-cluster similarity < `threshold`.
/// Returns cluster id per point.
pub fn average_linkage(sim: &Mat, threshold: f64) -> Vec<usize> {
    let n = sim.rows;
    assert!(sim.is_square());
    // Active cluster -> member count; merged clusters become inactive.
    let mut active: Vec<bool> = vec![true; n];
    let mut size: Vec<f64> = vec![1.0; n];
    let mut s = sim.clone(); // inter-cluster average similarity
    let mut parent: Vec<usize> = (0..n).collect();

    loop {
        // Find best active pair.
        let mut best = (f64::NEG_INFINITY, 0, 0);
        for i in 0..n {
            if !active[i] {
                continue;
            }
            for j in (i + 1)..n {
                if !active[j] {
                    continue;
                }
                let v = s.get(i, j);
                if v > best.0 {
                    best = (v, i, j);
                }
            }
        }
        let (v, a, b) = best;
        if v < threshold || !v.is_finite() {
            break;
        }
        // Merge b into a with Lance-Williams average-linkage update.
        let (na, nb) = (size[a], size[b]);
        for k in 0..n {
            if !active[k] || k == a || k == b {
                continue;
            }
            let new = (na * s.get(a, k) + nb * s.get(b, k)) / (na + nb);
            s.set(a, k, new);
            s.set(k, a, new);
        }
        size[a] += size[b];
        active[b] = false;
        parent[b] = a;
    }
    // Path-compress to cluster representatives, then densify ids.
    let mut root = vec![0usize; n];
    for i in 0..n {
        let mut r = i;
        while parent[r] != r {
            r = parent[r];
        }
        root[i] = r;
    }
    let mut remap = std::collections::HashMap::new();
    let mut next = 0usize;
    root.iter()
        .map(|&r| {
            *remap.entry(r).or_insert_with(|| {
                let id = next;
                next += 1;
                id
            })
        })
        .collect()
}

/// Squared Euclidean distance between two equal-length points.
#[inline]
fn dist_sq(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// Index of the centroid closest to `p` (ties broken by lowest index, so
/// assignment is deterministic for every worker count).
fn nearest_centroid(p: &[f64], centroids: &Mat) -> usize {
    let mut best = (f64::INFINITY, 0usize);
    for c in 0..centroids.rows {
        let d = dist_sq(p, centroids.row(c));
        if d.total_cmp(&best.0) == std::cmp::Ordering::Less {
            best = (d, c);
        }
    }
    best.1
}

/// Lloyd's k-means over the rows of `points` with k-means++ seeding.
/// Returns (centroids k x d, assignment per point). `k` is clamped to
/// [1, n]; empty clusters keep their previous centroid. The O(n·k·d)
/// assignment step is sharded across the pool workers; every other step
/// is deterministic given `rng`, so the result is bit-identical for
/// every worker count.
pub fn kmeans(points: &Mat, k: usize, iters: usize, rng: &mut Rng) -> (Mat, Vec<usize>) {
    let (n, d) = (points.rows, points.cols);
    assert!(n > 0, "kmeans needs at least one point");
    let k = k.clamp(1, n);
    // k-means++ seeding: first centroid uniform, the rest proportional to
    // squared distance from the chosen set.
    let mut centroids = Mat::zeros(k, d);
    centroids.row_mut(0).copy_from_slice(points.row(rng.below(n)));
    let mut d2: Vec<f64> = (0..n).map(|i| dist_sq(points.row(i), centroids.row(0))).collect();
    for c in 1..k {
        let total: f64 = d2.iter().sum();
        let pick = if total > 0.0 && total.is_finite() {
            rng.weighted(&d2)
        } else {
            rng.below(n) // all points coincide (or degenerate): uniform
        };
        centroids.row_mut(c).copy_from_slice(points.row(pick));
        for (i, dd) in d2.iter_mut().enumerate() {
            *dd = dd.min(dist_sq(points.row(i), centroids.row(c)));
        }
    }
    let mut assign = vec![0usize; n];
    for _ in 0..iters.max(1) {
        // Assignment: independent per point, sharded on the pool.
        let workers = pool::auto_workers(n * k * d, 1 << 18);
        let chunks = pool::map_chunks(workers, n, 1, |r| {
            r.map(|i| nearest_centroid(points.row(i), &centroids))
                .collect::<Vec<usize>>()
        });
        let next: Vec<usize> = chunks.into_iter().flatten().collect();
        let moved = next != assign;
        assign = next;
        // Update: mean of each cluster's members.
        let mut sums = Mat::zeros(k, d);
        let mut counts = vec![0usize; k];
        for (i, &c) in assign.iter().enumerate() {
            counts[c] += 1;
            let row = sums.row_mut(c);
            for (s, &x) in row.iter_mut().zip(points.row(i)) {
                *s += x;
            }
        }
        for c in 0..k {
            if counts[c] > 0 {
                let inv = 1.0 / counts[c] as f64;
                let dst = centroids.row_mut(c);
                dst.copy_from_slice(sums.row(c));
                for o in dst.iter_mut() {
                    *o *= inv;
                }
            }
        }
        if !moved {
            break;
        }
    }
    (centroids, assign)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn block_sim(blocks: &[usize], within: f64, across: f64, noise: f64, rng: &mut Rng) -> Mat {
        let n = blocks.len();
        let mut m = Mat::from_fn(n, n, |i, j| {
            let base = if blocks[i] == blocks[j] { within } else { across };
            base + noise * rng.normal()
        });
        for i in 0..n {
            m.set(i, i, 1.0);
        }
        m.symmetrized()
    }

    #[test]
    fn recovers_planted_blocks() {
        let mut rng = Rng::new(1);
        let blocks: Vec<usize> = (0..30).map(|i| i / 10).collect();
        let sim = block_sim(&blocks, 0.8, 0.1, 0.03, &mut rng);
        let got = average_linkage(&sim, 0.45);
        // Same block -> same cluster; different blocks -> different.
        for i in 0..30 {
            for j in 0..30 {
                assert_eq!(
                    got[i] == got[j],
                    blocks[i] == blocks[j],
                    "pair ({i},{j})"
                );
            }
        }
    }

    #[test]
    fn high_threshold_yields_singletons() {
        let mut rng = Rng::new(2);
        let blocks: Vec<usize> = (0..12).map(|i| i / 4).collect();
        let sim = block_sim(&blocks, 0.6, 0.1, 0.01, &mut rng);
        let got = average_linkage(&sim, 10.0);
        let distinct: std::collections::HashSet<usize> = got.iter().copied().collect();
        assert_eq!(distinct.len(), 12);
    }

    fn blob_points(blocks: &[usize], spread: f64, rng: &mut Rng) -> Mat {
        let d = 4;
        let centers: Vec<Vec<f64>> = (0..4)
            .map(|c| (0..d).map(|t| ((c * d + t) as f64) * 3.0).collect())
            .collect();
        Mat::from_fn(blocks.len(), d, |i, t| {
            centers[blocks[i]][t] + spread * rng.normal()
        })
    }

    #[test]
    fn kmeans_recovers_separated_blobs() {
        let mut rng = Rng::new(4);
        let blocks: Vec<usize> = (0..40).map(|i| i % 4).collect();
        let pts = blob_points(&blocks, 0.05, &mut rng);
        let (centroids, assign) = kmeans(&pts, 4, 20, &mut rng);
        assert_eq!(centroids.rows, 4);
        assert_eq!(assign.len(), 40);
        for i in 0..40 {
            for j in 0..40 {
                assert_eq!(
                    assign[i] == assign[j],
                    blocks[i] == blocks[j],
                    "pair ({i},{j})"
                );
            }
        }
    }

    #[test]
    fn kmeans_clamps_k_and_is_worker_invariant() {
        let mut rng = Rng::new(5);
        let pts = Mat::gaussian(6, 3, &mut rng);
        let (c, a) = kmeans(&pts, 50, 5, &mut Rng::new(9));
        assert_eq!(c.rows, 6, "k must clamp to n");
        assert_eq!(a.len(), 6);
        let serial = crate::util::pool::with_workers(1, || kmeans(&pts, 3, 8, &mut Rng::new(11)));
        let parallel = crate::util::pool::with_workers(4, || kmeans(&pts, 3, 8, &mut Rng::new(11)));
        assert_eq!(serial.1, parallel.1, "assignment must be worker-invariant");
        assert_eq!(serial.0.data, parallel.0.data, "centroids must be worker-invariant");
    }

    #[test]
    fn low_threshold_merges_everything() {
        let mut rng = Rng::new(3);
        let blocks: Vec<usize> = (0..12).map(|i| i / 4).collect();
        let sim = block_sim(&blocks, 0.6, 0.1, 0.01, &mut rng);
        let got = average_linkage(&sim, -10.0);
        assert!(got.iter().all(|&c| c == got[0]));
    }
}
