//! Downstream evaluation metrics: Pearson/Spearman correlation (STS-B),
//! accuracy (RTE), binary F1 (MRPC), and threshold calibration.

use crate::util::stats::ranks;

pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    if n < 2.0 {
        return 0.0;
    }
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for (a, b) in x.iter().zip(y) {
        cov += (a - mx) * (b - my);
        vx += (a - mx).powi(2);
        vy += (b - my).powi(2);
    }
    if vx == 0.0 || vy == 0.0 {
        return 0.0;
    }
    cov / (vx.sqrt() * vy.sqrt())
}

pub fn spearman(x: &[f64], y: &[f64]) -> f64 {
    pearson(&ranks(x), &ranks(y))
}

pub fn accuracy(pred: &[bool], gold: &[bool]) -> f64 {
    assert_eq!(pred.len(), gold.len());
    pred.iter().zip(gold).filter(|(p, g)| p == g).count() as f64 / pred.len() as f64
}

/// Binary F1 on the positive class.
pub fn f1(pred: &[bool], gold: &[bool]) -> f64 {
    assert_eq!(pred.len(), gold.len());
    let tp = pred.iter().zip(gold).filter(|(p, g)| **p && **g).count() as f64;
    let fp = pred.iter().zip(gold).filter(|(p, g)| **p && !**g).count() as f64;
    let fn_ = pred.iter().zip(gold).filter(|(p, g)| !**p && **g).count() as f64;
    if tp == 0.0 {
        return 0.0;
    }
    let prec = tp / (tp + fp);
    let rec = tp / (tp + fn_);
    2.0 * prec * rec / (prec + rec)
}

/// Calibrate a decision threshold on (score, gold) pairs by maximizing F1
/// over candidate thresholds (the per-method calibration used for the
/// MRPC/RTE rows; identical procedure for exact and approximate scores).
pub fn calibrate_threshold(scores: &[f64], gold: &[bool]) -> f64 {
    let mut cands: Vec<f64> = scores.to_vec();
    cands.sort_by(|a, b| a.partial_cmp(b).unwrap());
    cands.dedup();
    let mut best = (f64::NEG_INFINITY, 0.0);
    for w in cands.windows(2) {
        let thr = 0.5 * (w[0] + w[1]);
        let pred: Vec<bool> = scores.iter().map(|&s| s > thr).collect();
        let score = f1(&pred, gold);
        if score > best.0 {
            best = (score, thr);
        }
    }
    best.1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pearson_perfect_and_inverse() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let z = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&x, &z) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_monotone_invariant() {
        let x = [1.0, 2.0, 3.0, 4.0, 5.0];
        let y = [1.0, 8.0, 27.0, 64.0, 125.0]; // monotone but nonlinear
        assert!((spearman(&x, &y) - 1.0).abs() < 1e-12);
        assert!(pearson(&x, &y) < 1.0);
    }

    #[test]
    fn f1_hand_worked() {
        // tp=2, fp=1, fn=1 -> P=2/3, R=2/3, F1=2/3.
        let pred = [true, true, true, false, false];
        let gold = [true, true, false, true, false];
        assert!((f1(&pred, &gold) - 2.0 / 3.0).abs() < 1e-12);
        assert!((accuracy(&pred, &gold) - 0.6).abs() < 1e-12);
    }

    #[test]
    fn calibration_finds_separating_threshold() {
        let scores = [0.1, 0.2, 0.3, 0.8, 0.9, 0.95];
        let gold = [false, false, false, true, true, true];
        let thr = calibrate_threshold(&scores, &gold);
        assert!(thr > 0.3 && thr < 0.8);
        let pred: Vec<bool> = scores.iter().map(|&s| s > thr).collect();
        assert!((f1(&pred, &gold) - 1.0).abs() < 1e-12);
    }
}
