//! Downstream tasks the paper evaluates through the approximated
//! matrices: SVM document classification, GLUE-style correlation/F1
//! scoring, and agglomerative-clustering coreference with CoNLL metrics.

pub mod cluster;
pub mod coref_metrics;
pub mod metrics;
pub mod svm;

pub use cluster::{average_linkage, kmeans};
pub use coref_metrics::{b_cubed, ceaf_e, conll_f1, muc};
pub use metrics::{accuracy, calibrate_threshold, f1, pearson, spearman};
pub use svm::{standardize, LinearSvm, SvmConfig};
