//! Linear multi-class SVM — the LIBLINEAR substitute. One-vs-rest hinge
//! loss with L2 regularization trained by Pegasos-style SGD (Shalev-Shwartz
//! et al.), classifying the embedding features Z produced by the
//! approximation methods (Table 1's downstream task).

use crate::linalg::{dot, Mat};
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct SvmConfig {
    /// Regularization λ (the paper tunes LIBLINEAR's C = 1/(λ n)).
    pub lambda: f64,
    pub epochs: usize,
}

impl Default for SvmConfig {
    fn default() -> Self {
        SvmConfig {
            lambda: 1e-2,
            epochs: 40,
        }
    }
}

pub struct LinearSvm {
    /// classes x (dim + 1) — last column is the bias.
    w: Mat,
    pub classes: usize,
}

impl LinearSvm {
    /// Train one-vs-rest on rows of `x` with integer labels.
    pub fn train(
        x: &Mat,
        labels: &[usize],
        classes: usize,
        cfg: SvmConfig,
        rng: &mut Rng,
    ) -> LinearSvm {
        assert_eq!(x.rows, labels.len());
        let d = x.cols;
        let mut w = Mat::zeros(classes, d + 1);
        let n = x.rows;
        let mut order: Vec<usize> = (0..n).collect();
        let mut t: f64 = 1.0;
        for _ in 0..cfg.epochs {
            rng.shuffle(&mut order);
            for &i in &order {
                let eta = 1.0 / (cfg.lambda * t);
                t += 1.0;
                let xi = x.row(i);
                for c in 0..classes {
                    let y = if labels[i] == c { 1.0 } else { -1.0 };
                    let wc = w.row_mut(c);
                    let margin = y * (dot(&wc[..d], xi) + wc[d]);
                    // L2 shrink.
                    let shrink = 1.0 - eta * cfg.lambda;
                    for v in wc[..d].iter_mut() {
                        *v *= shrink;
                    }
                    if margin < 1.0 {
                        for (v, &xv) in wc[..d].iter_mut().zip(xi) {
                            *v += eta * y * xv;
                        }
                        wc[d] += eta * y * 0.1; // bias learns slower
                    }
                }
            }
        }
        LinearSvm { w, classes }
    }

    pub fn predict_one(&self, x: &[f64]) -> usize {
        let d = self.w.cols - 1;
        let mut best = (0, f64::NEG_INFINITY);
        for c in 0..self.classes {
            let wc = self.w.row(c);
            let score = dot(&wc[..d], x) + wc[d];
            if score > best.1 {
                best = (c, score);
            }
        }
        best.0
    }

    pub fn predict(&self, x: &Mat) -> Vec<usize> {
        (0..x.rows).map(|i| self.predict_one(x.row(i))).collect()
    }

    pub fn accuracy(&self, x: &Mat, labels: &[usize]) -> f64 {
        let pred = self.predict(x);
        let correct = pred
            .iter()
            .zip(labels)
            .filter(|(p, l)| p == l)
            .count();
        correct as f64 / labels.len() as f64
    }
}

/// Standardize features column-wise using train-split statistics (fit on
/// train, apply to all). Returns the transformed copy.
pub fn standardize(x: &Mat, train_rows: &[usize]) -> Mat {
    let d = x.cols;
    let m = train_rows.len() as f64;
    let mut mean = vec![0.0; d];
    let mut var = vec![0.0; d];
    for &i in train_rows {
        for (j, v) in x.row(i).iter().enumerate() {
            mean[j] += v / m;
        }
    }
    for &i in train_rows {
        for (j, v) in x.row(i).iter().enumerate() {
            var[j] += (v - mean[j]).powi(2) / m;
        }
    }
    let std: Vec<f64> = var.iter().map(|v| v.sqrt().max(1e-9)).collect();
    Mat::from_fn(x.rows, d, |i, j| (x.get(i, j) - mean[j]) / std[j])
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Linearly separable blobs must be learned to high accuracy.
    #[test]
    fn separable_blobs() {
        let mut rng = Rng::new(1);
        let n_per = 40;
        let classes = 3;
        let centers = [[4.0, 0.0], [-4.0, 2.0], [0.0, -5.0]];
        let mut rows = Vec::new();
        let mut labels = Vec::new();
        for (c, center) in centers.iter().enumerate() {
            for _ in 0..n_per {
                rows.push(vec![
                    center[0] + rng.normal() * 0.6,
                    center[1] + rng.normal() * 0.6,
                ]);
                labels.push(c);
            }
        }
        let x = Mat::from_rows(rows);
        let svm = LinearSvm::train(&x, &labels, classes, SvmConfig::default(), &mut rng);
        assert!(svm.accuracy(&x, &labels) > 0.95);
    }

    #[test]
    fn generalizes_to_test_split() {
        let mut rng = Rng::new(2);
        let make = |n: usize, rng: &mut Rng| {
            let mut rows = Vec::new();
            let mut labels = Vec::new();
            for i in 0..n {
                let c = i % 2;
                let off = if c == 0 { 2.5 } else { -2.5 };
                rows.push(vec![off + rng.normal(), rng.normal()]);
                labels.push(c);
            }
            (Mat::from_rows(rows), labels)
        };
        let (xtr, ytr) = make(120, &mut rng);
        let (xte, yte) = make(60, &mut rng);
        let svm = LinearSvm::train(&xtr, &ytr, 2, SvmConfig::default(), &mut rng);
        assert!(svm.accuracy(&xte, &yte) > 0.9);
    }

    #[test]
    fn standardize_zero_mean_unit_var_on_train() {
        let mut rng = Rng::new(3);
        let x = Mat::gaussian(50, 4, &mut rng).scale(3.0);
        let train: Vec<usize> = (0..30).collect();
        let z = standardize(&x, &train);
        for j in 0..4 {
            let mean: f64 = train.iter().map(|&i| z.get(i, j)).sum::<f64>() / 30.0;
            let var: f64 = train.iter().map(|&i| z.get(i, j).powi(2)).sum::<f64>() / 30.0;
            assert!(mean.abs() < 1e-9);
            assert!((var - 1.0).abs() < 1e-6);
        }
    }
}
