//! Coreference metrics: MUC, B³, CEAF-e and their average (CoNLL F1,
//! Pradhan et al. 2014) — the evaluation used for the ECB+ experiments
//! (Fig. 4). CEAF-e uses an optimal cluster alignment computed with the
//! Hungarian algorithm (implemented from scratch below).

use std::collections::HashMap;

/// Clusters as lists of member indices, from per-point cluster ids.
fn to_clusters(ids: &[usize]) -> Vec<Vec<usize>> {
    let mut m: HashMap<usize, Vec<usize>> = HashMap::new();
    for (i, &c) in ids.iter().enumerate() {
        m.entry(c).or_default().push(i);
    }
    let mut v: Vec<Vec<usize>> = m.into_values().collect();
    v.sort_by_key(|c| c[0]);
    v
}

fn prf(p_num: f64, p_den: f64, r_num: f64, r_den: f64) -> (f64, f64, f64) {
    let p = if p_den > 0.0 { p_num / p_den } else { 0.0 };
    let r = if r_den > 0.0 { r_num / r_den } else { 0.0 };
    let f = if p + r > 0.0 { 2.0 * p * r / (p + r) } else { 0.0 };
    (p, r, f)
}

/// MUC (link-based): recall = Σ (|g| - partitions(g, pred)) / Σ (|g| - 1).
pub fn muc(pred: &[usize], gold: &[usize]) -> (f64, f64, f64) {
    let count = |from: &[usize], to: &[usize]| -> (f64, f64) {
        let clusters = to_clusters(from);
        let mut num = 0.0;
        let mut den = 0.0;
        for c in &clusters {
            if c.len() < 2 {
                continue;
            }
            let mut parts = std::collections::HashSet::new();
            for &m in c {
                parts.insert(to[m]);
            }
            num += (c.len() - parts.len()) as f64;
            den += (c.len() - 1) as f64;
        }
        (num, den)
    };
    let (rn, rd) = count(gold, pred);
    let (pn, pd) = count(pred, gold);
    prf(pn, pd, rn, rd)
}

/// B³ (mention-based).
pub fn b_cubed(pred: &[usize], gold: &[usize]) -> (f64, f64, f64) {
    let n = pred.len();
    let pred_c = to_clusters(pred);
    let gold_c = to_clusters(gold);
    let pred_of: Vec<usize> = {
        let mut v = vec![0; n];
        for (ci, c) in pred_c.iter().enumerate() {
            for &m in c {
                v[m] = ci;
            }
        }
        v
    };
    let gold_of: Vec<usize> = {
        let mut v = vec![0; n];
        for (ci, c) in gold_c.iter().enumerate() {
            for &m in c {
                v[m] = ci;
            }
        }
        v
    };
    // Overlap counts per (pred cluster, gold cluster).
    let mut overlap: HashMap<(usize, usize), f64> = HashMap::new();
    for i in 0..n {
        *overlap.entry((pred_of[i], gold_of[i])).or_insert(0.0) += 1.0;
    }
    let mut p_sum = 0.0;
    let mut r_sum = 0.0;
    for (&(pc, gc), &o) in &overlap {
        p_sum += o * o / pred_c[pc].len() as f64;
        r_sum += o * o / gold_c[gc].len() as f64;
    }
    prf(p_sum, n as f64, r_sum, n as f64)
}

/// CEAF-e (entity-based, φ4 similarity) with optimal alignment.
pub fn ceaf_e(pred: &[usize], gold: &[usize]) -> (f64, f64, f64) {
    let pred_c = to_clusters(pred);
    let gold_c = to_clusters(gold);
    let phi4 = |a: &[usize], b: &[usize]| {
        let sa: std::collections::HashSet<usize> = a.iter().copied().collect();
        let inter = b.iter().filter(|m| sa.contains(m)).count() as f64;
        2.0 * inter / (a.len() + b.len()) as f64
    };
    let rows = pred_c.len();
    let cols = gold_c.len();
    let dim = rows.max(cols);
    // Cost matrix for Hungarian (maximize phi4 -> minimize (max - phi4)).
    let mut score = vec![vec![0.0; dim]; dim];
    for (i, row) in score.iter_mut().enumerate().take(rows) {
        for (j, cell) in row.iter_mut().enumerate().take(cols) {
            *cell = phi4(&pred_c[i], &gold_c[j]);
        }
    }
    let total = hungarian_max(&score);
    prf(total, rows as f64, total, cols as f64)
}

/// CoNLL F1 = mean of MUC, B³, CEAF-e F1s.
pub fn conll_f1(pred: &[usize], gold: &[usize]) -> f64 {
    (muc(pred, gold).2 + b_cubed(pred, gold).2 + ceaf_e(pred, gold).2) / 3.0
}

/// Maximum-weight perfect matching on a square score matrix via the
/// Hungarian (Kuhn-Munkres) algorithm, O(n³). Returns total matched score.
pub fn hungarian_max(score: &[Vec<f64>]) -> f64 {
    let n = score.len();
    if n == 0 {
        return 0.0;
    }
    let big = score
        .iter()
        .flat_map(|r| r.iter())
        .cloned()
        .fold(0.0f64, f64::max);
    // Convert to min-cost with the JV-style potentials formulation.
    // cost[i][j] = big - score[i][j] >= 0.
    let inf = f64::INFINITY;
    let mut u = vec![0.0; n + 1];
    let mut v = vec![0.0; n + 1];
    let mut p = vec![0usize; n + 1]; // p[j] = row matched to column j
    let mut way = vec![0usize; n + 1];
    for i in 1..=n {
        p[0] = i;
        let mut j0 = 0usize;
        let mut minv = vec![inf; n + 1];
        let mut used = vec![false; n + 1];
        loop {
            used[j0] = true;
            let i0 = p[j0];
            let mut delta = inf;
            let mut j1 = 0usize;
            for j in 1..=n {
                if used[j] {
                    continue;
                }
                let cost = big - score[i0 - 1][j - 1];
                let cur = cost - u[i0] - v[j];
                if cur < minv[j] {
                    minv[j] = cur;
                    way[j] = j0;
                }
                if minv[j] < delta {
                    delta = minv[j];
                    j1 = j;
                }
            }
            for j in 0..=n {
                if used[j] {
                    u[p[j]] += delta;
                    v[j] -= delta;
                } else {
                    minv[j] -= delta;
                }
            }
            j0 = j1;
            if p[j0] == 0 {
                break;
            }
        }
        loop {
            let j1 = way[j0];
            p[j0] = p[j1];
            j0 = j1;
            if j0 == 0 {
                break;
            }
        }
    }
    let mut total = 0.0;
    for j in 1..=n {
        if p[j] != 0 {
            total += score[p[j] - 1][j - 1];
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_prediction_scores_one() {
        let gold = vec![0, 0, 1, 1, 2, 2, 2];
        assert!((muc(&gold, &gold).2 - 1.0).abs() < 1e-12);
        assert!((b_cubed(&gold, &gold).2 - 1.0).abs() < 1e-12);
        assert!((ceaf_e(&gold, &gold).2 - 1.0).abs() < 1e-12);
        assert!((conll_f1(&gold, &gold) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn all_singletons_muc_zero() {
        let gold = vec![0, 0, 1, 1];
        let pred = vec![0, 1, 2, 3];
        let (_, _, f) = muc(&pred, &gold);
        assert_eq!(f, 0.0);
        // B³ recall suffers but precision is 1.
        let (p, r, _) = b_cubed(&pred, &gold);
        assert!((p - 1.0).abs() < 1e-12);
        assert!(r < 1.0);
    }

    #[test]
    fn b_cubed_hand_worked() {
        // Gold {0,1},{2}; pred {0,1,2}.
        let gold = vec![0, 0, 1];
        let pred = vec![0, 0, 0];
        let (p, r, _) = b_cubed(&pred, &gold);
        // precision: mentions 0,1 -> 2/3 each; mention 2 -> 1/3. mean = 5/9.
        assert!((p - 5.0 / 9.0).abs() < 1e-12);
        // recall: mentions 0,1 -> 2/2; mention 2 -> 1/1 -> 1.
        assert!((r - 1.0).abs() < 1e-12);
    }

    #[test]
    fn hungarian_small_cases() {
        let s = vec![vec![1.0, 2.0], vec![3.0, 1.0]];
        assert!((hungarian_max(&s) - 5.0).abs() < 1e-9);
        let s = vec![
            vec![0.9, 0.1, 0.0],
            vec![0.1, 0.8, 0.0],
            vec![0.0, 0.0, 0.7],
        ];
        assert!((hungarian_max(&s) - 2.4).abs() < 1e-9);
    }

    #[test]
    fn conll_monotone_in_quality() {
        let gold = vec![0, 0, 0, 1, 1, 1, 2, 2, 2];
        let good = vec![0, 0, 0, 1, 1, 1, 2, 2, 0]; // one error
        let bad = vec![0, 1, 2, 0, 1, 2, 0, 1, 2]; // scrambled
        let fg = conll_f1(&good, &gold);
        let fb = conll_f1(&bad, &gold);
        assert!(fg > fb, "good={fg} bad={fb}");
        assert!(fg > 0.6 && fg < 1.0);
    }
}
