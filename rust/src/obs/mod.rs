//! §Observability: dependency-free telemetry for the serving stack.
//!
//! Three pieces, layered on the paper's cost model (similarity
//! evaluations — Δ-calls — are the unit of spend):
//!
//! * [`span`] — nestable tracing spans with monotonic-clock timing and
//!   Δ-call/bytes counters attached at close, recorded into a
//!   thread-safe ring buffer ([`Recorder`]); process-global install via
//!   [`configure`], zero-cost when off.
//! * [`snapshot`] — [`MetricsSnapshot`]: one point-in-time capture of
//!   every `coordinator::Metrics` counter plus the latency histogram,
//!   with `delta()` for windowed rates.
//! * [`export`] — Prometheus-style text exposition and a JSON twin
//!   (round-trippable through `util::json`), served over the wire by
//!   `Query::Telemetry` so a sharded fleet reports per-shard health,
//!   epoch, and breaker state in one scrape.

pub mod export;
pub mod snapshot;
pub mod span;

pub use export::{from_json, prometheus, to_json};
pub use snapshot::MetricsSnapshot;
pub use span::{
    configure, oracle_span, oracle_total, recorder, span, Recorder, Span, SpanKind, SpanRecord,
    TelemetryConfig,
};
