//! Point-in-time capture of every serving counter.
//!
//! [`Metrics`] is a bag of relaxed atomics the hot paths bump lock-free;
//! a [`MetricsSnapshot`] reads them all once, giving operators a stable
//! document to export, diff, and rate. Counters are monotone, so two
//! captures taken in order are monotone field-by-field and
//! [`delta`](MetricsSnapshot::delta) windows never go negative — pinned
//! under concurrent writers by `tests/observability.rs`.
//!
//! Capture is per-counter atomic, not cross-counter transactional: a
//! writer racing the capture can land between two counter reads, so
//! derived cross-counter identities (e.g. histogram count vs. bucket sum)
//! may be off by in-flight increments. Each individual counter is exact.

use crate::coordinator::Metrics;

/// One consistent-enough reading of every [`Metrics`] counter plus the
/// latency histogram.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MetricsSnapshot {
    /// Every scalar counter, `(name, value)`, in the stable order defined
    /// by [`Metrics::counters`].
    pub counters: Vec<(String, u64)>,
    /// Histogram bucket upper bounds in µs; the final overflow bucket is
    /// implied (`+Inf`).
    pub latency_bucket_bounds: Vec<u64>,
    /// Per-bucket counts — `latency_bucket_bounds.len() + 1` entries.
    pub latency_buckets: Vec<u64>,
    pub latency_sum_us: u64,
    pub latency_count: u64,
}

impl MetricsSnapshot {
    pub fn capture(m: &Metrics) -> MetricsSnapshot {
        MetricsSnapshot {
            counters: m
                .counters()
                .into_iter()
                .map(|(name, v)| (name.to_string(), v))
                .collect(),
            latency_bucket_bounds: Metrics::latency_bucket_bounds().to_vec(),
            latency_buckets: m.latency_bucket_counts(),
            latency_sum_us: m.latency_sum_us(),
            latency_count: m.latency_count(),
        }
    }

    /// Value of one counter by name.
    pub fn get(&self, name: &str) -> Option<u64> {
        self.counters
            .iter()
            .find(|(n, _)| n == name)
            .map(|&(_, v)| v)
    }

    /// Windowed difference `self − earlier` for rate computation. Both
    /// snapshots must come from the same `Metrics` generation; fields are
    /// subtracted saturating so a mismatched pair degrades to zeros
    /// instead of wrapping.
    pub fn delta(&self, earlier: &MetricsSnapshot) -> MetricsSnapshot {
        let counters = self
            .counters
            .iter()
            .map(|(name, v)| {
                let before = earlier.get(name).unwrap_or(0);
                (name.clone(), v.saturating_sub(before))
            })
            .collect();
        let latency_buckets = self
            .latency_buckets
            .iter()
            .zip(earlier.latency_buckets.iter().chain(std::iter::repeat(&0)))
            .map(|(a, b)| a.saturating_sub(*b))
            .collect();
        MetricsSnapshot {
            counters,
            latency_bucket_bounds: self.latency_bucket_bounds.clone(),
            latency_buckets,
            latency_sum_us: self.latency_sum_us.saturating_sub(earlier.latency_sum_us),
            latency_count: self.latency_count.saturating_sub(earlier.latency_count),
        }
    }

    /// Mean recorded latency over this snapshot (or window), in µs.
    pub fn mean_latency_us(&self) -> f64 {
        if self.latency_count == 0 {
            return 0.0;
        }
        self.latency_sum_us as f64 / self.latency_count as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn capture_reads_every_counter_and_the_histogram() {
        let m = Metrics::new();
        m.record_batch(48, 64);
        m.record_query();
        m.record_topk(2, 5, 11);
        m.record_latency(Duration::from_micros(300));
        let snap = MetricsSnapshot::capture(&m);
        assert_eq!(snap.get("oracle_calls"), Some(48));
        assert_eq!(snap.get("queries"), Some(1));
        assert_eq!(snap.get("topk_queries"), Some(2));
        assert_eq!(snap.get("cells_pruned"), Some(11));
        assert_eq!(snap.get("no_such_counter"), None);
        assert_eq!(snap.latency_count, 1);
        assert_eq!(snap.latency_sum_us, 300);
        assert_eq!(
            snap.latency_buckets.len(),
            snap.latency_bucket_bounds.len() + 1
        );
        // 300µs lands in the (250, 500] bucket.
        let idx = snap
            .latency_bucket_bounds
            .iter()
            .position(|&b| 300 <= b)
            .unwrap();
        assert_eq!(snap.latency_buckets[idx], 1);
    }

    #[test]
    fn delta_windows_subtract_per_field() {
        let m = Metrics::new();
        m.record_batch(10, 16);
        m.record_latency(Duration::from_micros(40));
        let a = MetricsSnapshot::capture(&m);
        m.record_batch(7, 16);
        m.record_latency(Duration::from_micros(60));
        let b = MetricsSnapshot::capture(&m);
        let d = b.delta(&a);
        assert_eq!(d.get("oracle_calls"), Some(7));
        assert_eq!(d.get("batches"), Some(1));
        assert_eq!(d.latency_count, 1);
        assert_eq!(d.latency_sum_us, 60);
        assert!((d.mean_latency_us() - 60.0).abs() < 1e-12);
        // Self-delta is all zeros.
        let z = b.delta(&b);
        assert!(z.counters.iter().all(|&(_, v)| v == 0));
        assert_eq!(z.latency_count, 0);
    }
}
