//! Metrics exposition: Prometheus-style text plus a JSON twin.
//!
//! Both formats render a [`MetricsSnapshot`] — every counter and the full
//! latency histogram — so a scrape is one consistent capture, not a
//! racy sequence of reads. The JSON twin is parsed back by
//! [`from_json`] (via the dependency-free `util::json` parser), and
//! `to_json → from_json` round-trips the snapshot exactly — pinned by
//! `tests/observability.rs`.
//!
//! Counter names are prefixed `simmat_`; the histogram follows the
//! Prometheus convention of cumulative `_bucket{le="…"}` lines plus
//! `_sum`/`_count`. Shard-level gauges (`simmat_shard_up{shard="0"}` …)
//! are appended by `ShardedService::scrape`, which gathers per-shard
//! health over the wire with `Query::Telemetry`.

use std::fmt::Write as _;

use crate::obs::snapshot::MetricsSnapshot;
use crate::util::json::Json;

/// Prometheus-style text exposition of one snapshot.
pub fn prometheus(snap: &MetricsSnapshot) -> String {
    let mut out = String::new();
    for (name, v) in &snap.counters {
        let _ = writeln!(out, "# TYPE simmat_{name} counter");
        let _ = writeln!(out, "simmat_{name} {v}");
    }
    let _ = writeln!(out, "# TYPE simmat_latency_us histogram");
    let mut cum = 0u64;
    for (bound, c) in snap.latency_bucket_bounds.iter().zip(&snap.latency_buckets) {
        cum += c;
        let _ = writeln!(out, "simmat_latency_us_bucket{{le=\"{bound}\"}} {cum}");
    }
    cum += snap.latency_buckets.last().copied().unwrap_or(0);
    let _ = writeln!(out, "simmat_latency_us_bucket{{le=\"+Inf\"}} {cum}");
    let _ = writeln!(out, "simmat_latency_us_sum {}", snap.latency_sum_us);
    let _ = writeln!(out, "simmat_latency_us_count {}", snap.latency_count);
    out
}

/// JSON twin of [`prometheus`]. Counters are an ordered array of
/// `{"name", "value"}` objects so the snapshot's stable order survives
/// the trip through parsers that hash object keys.
pub fn to_json(snap: &MetricsSnapshot) -> String {
    let mut out = String::from("{\n  \"counters\": [\n");
    for (i, (name, v)) in snap.counters.iter().enumerate() {
        let comma = if i + 1 == snap.counters.len() { "" } else { "," };
        let _ = writeln!(out, "    {{\"name\": \"{name}\", \"value\": {v}}}{comma}");
    }
    let join = |xs: &[u64]| {
        xs.iter()
            .map(|x| x.to_string())
            .collect::<Vec<_>>()
            .join(", ")
    };
    let _ = write!(
        out,
        "  ],\n  \"latency_us\": {{\n    \"bounds\": [{}],\n    \"buckets\": [{}],\n    \
         \"sum\": {},\n    \"count\": {}\n  }}\n}}\n",
        join(&snap.latency_bucket_bounds),
        join(&snap.latency_buckets),
        snap.latency_sum_us,
        snap.latency_count,
    );
    out
}

fn req_u64(j: &Json, what: &str) -> Result<u64, String> {
    let v = j.as_f64().ok_or_else(|| format!("{what}: not a number"))?;
    if v < 0.0 || v.fract() != 0.0 {
        return Err(format!("{what}: not a u64: {v}"));
    }
    Ok(v as u64)
}

fn req_u64_vec(j: &Json, what: &str) -> Result<Vec<u64>, String> {
    j.as_arr()
        .ok_or_else(|| format!("{what}: not an array"))?
        .iter()
        .map(|x| req_u64(x, what))
        .collect()
}

/// Parse a [`to_json`] document back into the snapshot it rendered.
pub fn from_json(src: &str) -> Result<MetricsSnapshot, String> {
    let doc = Json::parse(src)?;
    let counters = doc
        .get("counters")
        .and_then(|c| c.as_arr())
        .ok_or("missing counters array")?
        .iter()
        .map(|entry| {
            let name = entry
                .get("name")
                .and_then(|n| n.as_str())
                .ok_or("counter entry missing name")?
                .to_string();
            let value = req_u64(entry.get("value").ok_or("counter entry missing value")?, "value")?;
            Ok((name, value))
        })
        .collect::<Result<Vec<_>, String>>()?;
    let lat = doc.get("latency_us").ok_or("missing latency_us")?;
    Ok(MetricsSnapshot {
        counters,
        latency_bucket_bounds: req_u64_vec(lat.get("bounds").ok_or("missing bounds")?, "bounds")?,
        latency_buckets: req_u64_vec(lat.get("buckets").ok_or("missing buckets")?, "buckets")?,
        latency_sum_us: req_u64(lat.get("sum").ok_or("missing sum")?, "sum")?,
        latency_count: req_u64(lat.get("count").ok_or("missing count")?, "count")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Metrics;
    use std::time::Duration;

    fn busy_metrics() -> Metrics {
        let m = Metrics::new();
        m.record_batch(48, 64);
        m.record_batch(64, 64);
        m.record_query();
        m.record_inserts(3, 120);
        m.record_topk(5, 9, 21);
        m.record_rerank(40);
        m.record_shard_calls(6);
        m.record_latency(Duration::from_micros(42));
        m.record_latency(Duration::from_micros(900));
        m
    }

    #[test]
    fn json_round_trips_exactly() {
        let snap = MetricsSnapshot::capture(&busy_metrics());
        let back = from_json(&to_json(&snap)).unwrap();
        assert_eq!(back, snap);
    }

    #[test]
    fn prometheus_exposes_every_counter_and_cumulative_histogram() {
        let snap = MetricsSnapshot::capture(&busy_metrics());
        let text = prometheus(&snap);
        for (name, v) in &snap.counters {
            assert!(
                text.contains(&format!("simmat_{name} {v}")),
                "missing {name} in:\n{text}"
            );
        }
        // +Inf bucket equals the total observation count.
        assert!(text.contains(&format!(
            "simmat_latency_us_bucket{{le=\"+Inf\"}} {}",
            snap.latency_count
        )));
        assert!(text.contains(&format!("simmat_latency_us_sum {}", snap.latency_sum_us)));
        // le bounds are cumulative and monotone.
        let mut last = 0u64;
        for line in text.lines().filter(|l| l.contains("_bucket{le=")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= last, "non-monotone histogram line: {line}");
            last = v;
        }
    }

    #[test]
    fn from_json_rejects_malformed_documents() {
        assert!(from_json("{}").is_err());
        assert!(from_json("not json").is_err());
        assert!(from_json("{\"counters\": [{\"name\": \"x\"}]}").is_err());
    }
}
