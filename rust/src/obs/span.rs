//! Structured tracing spans: lightweight, nestable, dependency-free.
//!
//! The paper's cost model counts similarity evaluations (Δ-calls), so a
//! trace must decompose a query or insert into exactly those units. A
//! [`Span`] times a stage on the monotonic clock and carries counters
//! attached before close: Δ-calls, bytes, and free-form `u64` attributes
//! (e.g. IVF cells scanned/pruned). Finished spans land in a thread-safe
//! [`Recorder`] ring buffer, oldest-first eviction, drops counted.
//!
//! ## Attribution discipline
//!
//! Δ-calls attach at exactly the sites where pairs cross into
//! `SimOracle::eval*` — those spans are [`SpanKind::Oracle`]:
//!
//! * `oracle.flush` — a batcher chunk submitted to the inner oracle
//!   (requested pairs, once each);
//! * `oracle.retry` — a fault-layer re-buy of one retry chunk;
//! * `drift.probe` — the drift monitor's requested probe pairs (probes
//!   bypass the batcher; any fault-layer re-buys ride `oracle.retry`);
//! * `rerank.exact` — the budgeted exact re-scoring gather, which takes
//!   the caller's raw oracle by construction.
//!
//! Every other span is [`SpanKind::Stage`]: it times its stage and may
//! carry an *informational* Δ-call figure (e.g. a gather plan's predicted
//! cost) without entering the accounting sum. [`oracle_total`] therefore
//! equals a `CountingOracle`'s metered total exactly — pinned by
//! `tests/observability.rs`. Do not stack two accounting wrappers (e.g. a
//! `BatchingOracle` over another) or pairs would be attributed twice.
//!
//! ## Scope and zero-cost disabled mode
//!
//! The recorder is process-global, installed with [`configure`]: pool
//! workers and transport threads record into the same ring, so gathers
//! sharded across the pool stay fully attributed. Telemetry is **off by
//! default**; while off, [`span`] is one relaxed atomic load — no clock
//! read, no lock, no allocation (pinned ≈0 overhead by the microbench's
//! `BENCH_obs.json` assert).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::Instant;

/// Recover the guard from a poisoned lock: telemetry state is a ring of
/// plain records, valid whatever a panicking recorder observed.
fn relock<T>(r: Result<T, PoisonError<T>>) -> T {
    r.unwrap_or_else(|e| e.into_inner())
}

/// Telemetry switch + ring capacity. Off by default.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TelemetryConfig {
    pub enabled: bool,
    /// Ring-buffer capacity in span records; oldest evicted first.
    pub capacity: usize,
}

impl TelemetryConfig {
    /// Enabled with the default ring capacity (4096 spans).
    pub fn on() -> TelemetryConfig {
        TelemetryConfig {
            enabled: true,
            capacity: 4096,
        }
    }

    /// Disabled: spans are inert and cost one atomic load.
    pub fn off() -> TelemetryConfig {
        TelemetryConfig {
            enabled: false,
            capacity: 0,
        }
    }

    pub fn capacity(mut self, cap: usize) -> TelemetryConfig {
        self.capacity = cap.max(1);
        self
    }
}

impl Default for TelemetryConfig {
    fn default() -> TelemetryConfig {
        TelemetryConfig::off()
    }
}

/// Whether a span's `delta_calls` participates in the exact Δ accounting
/// sum ([`Oracle`](SpanKind::Oracle)) or is stage-level attribution
/// ([`Stage`](SpanKind::Stage)). See the module docs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    Stage,
    Oracle,
}

/// One finished span, as stored in the [`Recorder`] ring.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SpanRecord {
    pub name: &'static str,
    pub kind: SpanKind,
    /// Nesting depth on the recording thread (0 = root on that thread).
    pub depth: u32,
    /// Monotonic start offset from the recorder's creation, nanoseconds.
    pub start_ns: u64,
    pub elapsed_ns: u64,
    /// Similarity evaluations attributed to this span (see module docs).
    pub delta_calls: u64,
    /// Bytes moved by this span (wire payloads, gathered matrices).
    pub bytes: u64,
    /// Free-form counters, e.g. `("cells_scanned", 12)`.
    pub attrs: Vec<(&'static str, u64)>,
}

struct Ring {
    buf: VecDeque<SpanRecord>,
    dropped: u64,
}

/// Thread-safe fixed-capacity ring of finished spans.
pub struct Recorder {
    origin: Instant,
    capacity: usize,
    ring: Mutex<Ring>,
}

impl Recorder {
    pub fn new(capacity: usize) -> Recorder {
        let capacity = capacity.max(1);
        Recorder {
            origin: Instant::now(),
            capacity,
            ring: Mutex::new(Ring {
                buf: VecDeque::with_capacity(capacity),
                dropped: 0,
            }),
        }
    }

    fn push(&self, rec: SpanRecord) {
        let mut g = relock(self.ring.lock());
        if g.buf.len() == self.capacity {
            g.buf.pop_front();
            g.dropped += 1;
        }
        g.buf.push_back(rec);
    }

    /// Drain every recorded span, oldest first.
    pub fn take(&self) -> Vec<SpanRecord> {
        relock(self.ring.lock()).buf.drain(..).collect()
    }

    /// Clone the current contents without draining, oldest first.
    pub fn snapshot(&self) -> Vec<SpanRecord> {
        relock(self.ring.lock()).buf.iter().cloned().collect()
    }

    /// Spans evicted because the ring was full.
    pub fn dropped(&self) -> u64 {
        relock(self.ring.lock()).dropped
    }

    /// Ring capacity the recorder was configured with.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        relock(self.ring.lock()).buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static CURRENT: Mutex<Option<Arc<Recorder>>> = Mutex::new(None);

thread_local! {
    static DEPTH: std::cell::Cell<u32> = const { std::cell::Cell::new(0) };
}

/// Install (or remove) the process-global recorder. Returns the handle
/// when enabling so callers can read traces back. Replaces any previous
/// recorder; spans already opened keep recording into the ring they
/// started with.
pub fn configure(cfg: TelemetryConfig) -> Option<Arc<Recorder>> {
    if cfg.enabled {
        let rec = Arc::new(Recorder::new(cfg.capacity));
        *relock(CURRENT.lock()) = Some(rec.clone());
        ENABLED.store(true, Ordering::Release);
        Some(rec)
    } else {
        ENABLED.store(false, Ordering::Release);
        *relock(CURRENT.lock()) = None;
        None
    }
}

/// The currently-installed recorder, if telemetry is on.
pub fn recorder() -> Option<Arc<Recorder>> {
    if !ENABLED.load(Ordering::Acquire) {
        return None;
    }
    relock(CURRENT.lock()).clone()
}

/// Open a stage-level span. Inert (and nearly free) when telemetry is off.
pub fn span(name: &'static str) -> Span {
    span_kind(name, SpanKind::Stage)
}

/// Open an oracle-boundary span: its `delta_calls` enter the exact
/// accounting sum ([`oracle_total`]). Only use where pairs are handed
/// directly to `SimOracle::eval*`.
pub fn oracle_span(name: &'static str) -> Span {
    span_kind(name, SpanKind::Oracle)
}

fn span_kind(name: &'static str, kind: SpanKind) -> Span {
    if !ENABLED.load(Ordering::Acquire) {
        return Span { inner: None };
    }
    let Some(rec) = recorder() else {
        return Span { inner: None };
    };
    let depth = DEPTH.with(|d| {
        let v = d.get();
        d.set(v + 1);
        v
    });
    Span {
        inner: Some(SpanInner {
            rec,
            name,
            kind,
            depth,
            start: Instant::now(),
            delta_calls: 0,
            bytes: 0,
            attrs: Vec::new(),
        }),
    }
}

struct SpanInner {
    rec: Arc<Recorder>,
    name: &'static str,
    kind: SpanKind,
    depth: u32,
    start: Instant,
    delta_calls: u64,
    bytes: u64,
    attrs: Vec<(&'static str, u64)>,
}

/// An open span; recording happens when it drops (RAII) so early returns
/// and `?` propagation still close the span.
pub struct Span {
    inner: Option<SpanInner>,
}

impl Span {
    /// False when telemetry is off — counter updates are no-ops then.
    pub fn is_active(&self) -> bool {
        self.inner.is_some()
    }

    pub fn add_calls(&mut self, n: u64) {
        if let Some(inner) = self.inner.as_mut() {
            inner.delta_calls += n;
        }
    }

    pub fn add_bytes(&mut self, n: u64) {
        if let Some(inner) = self.inner.as_mut() {
            inner.bytes += n;
        }
    }

    pub fn attr(&mut self, key: &'static str, value: u64) {
        if let Some(inner) = self.inner.as_mut() {
            inner.attrs.push((key, value));
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(inner) = self.inner.take() {
            DEPTH.with(|d| d.set(d.get().saturating_sub(1)));
            let start_ns = inner
                .start
                .checked_duration_since(inner.rec.origin)
                .unwrap_or_default()
                .as_nanos() as u64;
            let elapsed_ns = inner.start.elapsed().as_nanos() as u64;
            inner.rec.push(SpanRecord {
                name: inner.name,
                kind: inner.kind,
                depth: inner.depth,
                start_ns,
                elapsed_ns,
                delta_calls: inner.delta_calls,
                bytes: inner.bytes,
                attrs: inner.attrs,
            });
        }
    }
}

/// Exact Δ-call total of a trace: the sum over oracle-boundary spans.
/// Equals a `CountingOracle`'s metered total when the module-doc
/// discipline is followed (pinned by `tests/observability.rs`).
pub fn oracle_total(records: &[SpanRecord]) -> u64 {
    records
        .iter()
        .filter(|r| r.kind == SpanKind::Oracle)
        .map(|r| r.delta_calls)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    // The global recorder is shared by every test in this binary; this
    // lock serializes the ones that install it.
    fn obs_lock() -> std::sync::MutexGuard<'static, ()> {
        static GUARD: Mutex<()> = Mutex::new(());
        relock(GUARD.lock())
    }

    #[test]
    fn disabled_spans_are_inert() {
        let _g = obs_lock();
        configure(TelemetryConfig::off());
        let mut s = span("noop");
        assert!(!s.is_active());
        s.add_calls(7);
        s.attr("x", 1);
        drop(s);
        assert!(recorder().is_none());
    }

    #[test]
    fn spans_record_counters_depth_and_timing() {
        // Other lib tests may emit instrumented-code spans while our
        // recorder is installed, so assert only over our own (uniquely
        // named) spans rather than the whole trace.
        let _g = obs_lock();
        let rec = configure(TelemetryConfig::on()).unwrap();
        {
            let mut outer = span("test.span.outer");
            outer.add_calls(3);
            {
                let mut inner = oracle_span("test.span.inner");
                inner.add_calls(5);
                inner.add_bytes(64);
                inner.attr("cells_scanned", 4);
            }
            // Drop order: inner closed first, then outer.
        }
        configure(TelemetryConfig::off());
        let trace = rec.take();
        let mine: Vec<&SpanRecord> =
            trace.iter().filter(|r| r.name.starts_with("test.span.")).collect();
        assert_eq!(mine.len(), 2);
        let (inner, outer) = (mine[0], mine[1]);
        assert_eq!(inner.name, "test.span.inner");
        assert_eq!(inner.kind, SpanKind::Oracle);
        assert_eq!(inner.depth, 1);
        assert_eq!(inner.delta_calls, 5);
        assert_eq!(inner.bytes, 64);
        assert_eq!(inner.attrs, vec![("cells_scanned", 4)]);
        assert_eq!(outer.name, "test.span.outer");
        assert_eq!(outer.kind, SpanKind::Stage);
        assert_eq!(outer.depth, 0);
        // The child cannot start earlier or run longer than its parent.
        assert!(inner.start_ns >= outer.start_ns);
        assert!(inner.elapsed_ns <= outer.elapsed_ns);
        // Only the Oracle-kind span enters the accounting sum.
        let mine_owned: Vec<SpanRecord> = mine.into_iter().cloned().collect();
        assert_eq!(oracle_total(&mine_owned), 5);
    }

    #[test]
    fn ring_evicts_oldest_and_counts_drops() {
        // Exercises the ring on a local Recorder (no global install), so
        // concurrent tests cannot perturb the eviction accounting.
        let rec = Recorder::new(4);
        for i in 0..10u64 {
            rec.push(SpanRecord {
                name: "tick",
                kind: SpanKind::Stage,
                depth: 0,
                start_ns: i,
                elapsed_ns: 0,
                delta_calls: i,
                bytes: 0,
                attrs: Vec::new(),
            });
        }
        assert_eq!(rec.len(), 4);
        assert_eq!(rec.dropped(), 6);
        let trace = rec.take();
        let calls: Vec<u64> = trace.iter().map(|r| r.delta_calls).collect();
        assert_eq!(calls, vec![6, 7, 8, 9]);
        assert!(rec.is_empty());
    }

    #[test]
    fn spans_from_other_threads_share_the_ring() {
        let _g = obs_lock();
        let rec = configure(TelemetryConfig::on()).unwrap();
        let handles: Vec<_> = (0..4)
            .map(|_| {
                std::thread::spawn(|| {
                    let mut s = oracle_span("test.span.threaded");
                    s.add_calls(10);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        configure(TelemetryConfig::off());
        let mine: Vec<SpanRecord> = rec
            .take()
            .into_iter()
            .filter(|r| r.name == "test.span.threaded")
            .collect();
        assert_eq!(mine.len(), 4);
        assert_eq!(oracle_total(&mine), 40);
    }
}
