//! PJRT runtime: artifact manifest, executable loading/execution, and the
//! PJRT-backed similarity oracles. This is the only module that touches
//! the `xla` crate — everything above it sees plain `SimOracle`s.

pub mod manifest;
pub mod oracles;
pub mod pjrt;

pub use manifest::{default_artifacts_dir, Manifest};
pub use oracles::{CorefPjrtOracle, CrossEncoderPjrtOracle, PaddedDoc, SharedRuntime, WmdPjrtOracle};
pub use pjrt::Runtime;

use std::sync::{Arc, Mutex};

/// Load the default artifacts directory into a shared runtime.
pub fn shared_runtime() -> anyhow::Result<SharedRuntime> {
    let dir = default_artifacts_dir()
        .ok_or_else(|| anyhow::anyhow!("artifacts/ not found — run `make artifacts`"))?;
    Ok(Arc::new(Mutex::new(Runtime::load(dir)?)))
}

/// Load a subset of artifacts into a shared runtime (faster startup).
pub fn shared_runtime_subset(names: &[&str]) -> anyhow::Result<SharedRuntime> {
    let dir = default_artifacts_dir()
        .ok_or_else(|| anyhow::anyhow!("artifacts/ not found — run `make artifacts`"))?;
    Ok(Arc::new(Mutex::new(Runtime::load_subset(dir, names)?)))
}
