//! Artifact manifest: the Rust-side mirror of python/compile/shapes.py,
//! parsed from artifacts/manifest.json (written by aot.py). The runtime
//! pads and batches strictly to these shapes — PJRT executables are
//! shape-specialized.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    /// Input shapes (all f32).
    pub inputs: Vec<Vec<usize>>,
    /// Output shape (f32).
    pub output: Vec<usize>,
}

#[derive(Clone, Copy, Debug)]
pub struct WmdShapes {
    pub batch: usize,
    pub max_len: usize,
    pub dim: usize,
    pub sinkhorn_iters: usize,
    pub eps: f64,
}

#[derive(Clone, Copy, Debug)]
pub struct CrossEncoderShapes {
    pub batch: usize,
    pub seq: usize,
    pub dim: usize,
}

#[derive(Clone, Copy, Debug)]
pub struct CorefShapes {
    pub batch: usize,
    pub dim: usize,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: HashMap<String, ArtifactSpec>,
    pub wmd: WmdShapes,
    pub cross_encoder: CrossEncoderShapes,
    pub coref: CorefShapes,
}

fn shape_of(j: &Json) -> Result<Vec<usize>> {
    j.as_f64_vec()
        .map(|v| v.into_iter().map(|x| x as usize).collect())
        .ok_or_else(|| anyhow!("bad shape entry"))
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&src).map_err(|e| anyhow!("manifest parse: {e}"))?;

        let mut artifacts = HashMap::new();
        let arts = j
            .get("artifacts")
            .and_then(|a| a.as_obj())
            .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?;
        for (name, spec) in arts {
            let file = dir.join(
                spec.get("file")
                    .and_then(|f| f.as_str())
                    .ok_or_else(|| anyhow!("artifact {name} missing file"))?,
            );
            let inputs = spec
                .get("inputs")
                .and_then(|x| x.as_arr())
                .ok_or_else(|| anyhow!("artifact {name} missing inputs"))?
                .iter()
                .map(|i| shape_of(i.get("shape").ok_or_else(|| anyhow!("no shape"))?))
                .collect::<Result<Vec<_>>>()?;
            let output = shape_of(
                spec.get("output")
                    .and_then(|o| o.get("shape"))
                    .ok_or_else(|| anyhow!("artifact {name} missing output"))?,
            )?;
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file,
                    inputs,
                    output,
                },
            );
        }

        let shapes = j
            .get("shapes")
            .ok_or_else(|| anyhow!("manifest missing 'shapes'"))?;
        let wmd_j = shapes.get("wmd").ok_or_else(|| anyhow!("no wmd shapes"))?;
        let get = |o: &Json, k: &str| -> Result<f64> {
            o.get(k)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| anyhow!("shapes missing {k}"))
        };
        let wmd = WmdShapes {
            batch: get(wmd_j, "batch")? as usize,
            max_len: get(wmd_j, "max_len")? as usize,
            dim: get(wmd_j, "dim")? as usize,
            sinkhorn_iters: get(wmd_j, "sinkhorn_iters")? as usize,
            eps: get(wmd_j, "eps")?,
        };
        let ce_j = shapes
            .get("cross_encoder")
            .ok_or_else(|| anyhow!("no cross_encoder shapes"))?;
        let cross_encoder = CrossEncoderShapes {
            batch: get(ce_j, "batch")? as usize,
            seq: get(ce_j, "seq")? as usize,
            dim: get(ce_j, "dim")? as usize,
        };
        let co_j = shapes.get("coref").ok_or_else(|| anyhow!("no coref shapes"))?;
        let coref = CorefShapes {
            batch: get(co_j, "batch")? as usize,
            dim: get(co_j, "dim")? as usize,
        };
        Ok(Manifest {
            dir,
            artifacts,
            wmd,
            cross_encoder,
            coref,
        })
    }

    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))
    }
}

/// Locate the artifacts directory: $SIMMAT_ARTIFACTS or ./artifacts
/// (walking up from cwd so tests and benches work from target dirs).
pub fn default_artifacts_dir() -> Option<PathBuf> {
    if let Ok(p) = std::env::var("SIMMAT_ARTIFACTS") {
        return Some(PathBuf::from(p));
    }
    let mut dir = std::env::current_dir().ok()?;
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return Some(cand);
        }
        if !dir.pop() {
            return None;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_real_manifest_when_built() {
        let Some(dir) = default_artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert!(m.artifacts.contains_key("wmd_sim"));
        let spec = m.spec("wmd_sim").unwrap();
        assert_eq!(spec.inputs[0], vec![m.wmd.batch, m.wmd.max_len, m.wmd.dim]);
        assert_eq!(spec.output, vec![m.wmd.batch]);
        assert!(spec.file.exists());
    }
}
