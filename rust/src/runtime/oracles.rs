//! PJRT-backed similarity oracles — the production request path. Each
//! oracle packs index pairs into the fixed batch shape its artifact was
//! lowered for, executes through the shared [`Runtime`], and unpacks the
//! scores. Python is never involved.

use std::sync::{Arc, Mutex};

use anyhow::Result;

use super::pjrt::Runtime;
use crate::sim::wmd::Doc;
use crate::sim::SimOracle;

pub type SharedRuntime = Arc<Mutex<Runtime>>;

/// A document padded to the artifact's (max_len, dim) with zero weights on
/// padding rows (zero-weight rows carry no transport mass — see
/// kernels/sinkhorn.py).
#[derive(Clone, Debug)]
pub struct PaddedDoc {
    pub x: Vec<f32>, // max_len * dim
    pub w: Vec<f32>, // max_len
}

impl PaddedDoc {
    pub fn from_doc(doc: &Doc, max_len: usize, dim: usize) -> PaddedDoc {
        assert!(
            doc.len() <= max_len,
            "document length {} exceeds artifact max_len {max_len}",
            doc.len()
        );
        let mut x = vec![0.0f32; max_len * dim];
        let mut w = vec![0.0f32; max_len];
        for (i, word) in doc.words.iter().enumerate() {
            assert_eq!(word.len(), dim, "embedding dim mismatch");
            for (j, &v) in word.iter().enumerate() {
                x[i * dim + j] = v as f32;
            }
            w[i] = doc.weights[i] as f32;
        }
        PaddedDoc { x, w }
    }
}

/// exp(-γ·WMD) oracle over padded documents via the `wmd_sim` artifact
/// (L2 graph + L1 Pallas Sinkhorn kernel).
pub struct WmdPjrtOracle {
    rt: SharedRuntime,
    pub docs: Vec<PaddedDoc>,
    pub gamma: f32,
    batch: usize,
    max_len: usize,
    dim: usize,
}

impl WmdPjrtOracle {
    pub fn new(rt: SharedRuntime, docs: &[Doc], gamma: f64) -> Result<WmdPjrtOracle> {
        let (batch, max_len, dim) = {
            let r = rt.lock().unwrap();
            (r.manifest.wmd.batch, r.manifest.wmd.max_len, r.manifest.wmd.dim)
        };
        let padded = docs
            .iter()
            .map(|d| PaddedDoc::from_doc(d, max_len, dim))
            .collect();
        Ok(WmdPjrtOracle {
            rt,
            docs: padded,
            gamma: gamma as f32,
            batch,
            max_len,
            dim,
        })
    }

    /// Similarity of document i against an external padded document (WME
    /// random features). Batched over `externals`.
    pub fn sim_to_externals(&self, i: usize, externals: &[PaddedDoc]) -> Vec<f64> {
        let pairs: Vec<(&PaddedDoc, &PaddedDoc)> =
            externals.iter().map(|e| (&self.docs[i], e)).collect();
        self.run_doc_pairs(&pairs)
    }

    fn run_doc_pairs(&self, pairs: &[(&PaddedDoc, &PaddedDoc)]) -> Vec<f64> {
        let (b, l, d) = (self.batch, self.max_len, self.dim);
        let mut out = Vec::with_capacity(pairs.len());
        for chunk in pairs.chunks(b) {
            let mut x1 = vec![0.0f32; b * l * d];
            let mut w1 = vec![0.0f32; b * l];
            let mut x2 = vec![0.0f32; b * l * d];
            let mut w2 = vec![0.0f32; b * l];
            for slot in 0..b {
                // Pad the final partial chunk by repeating its first pair.
                let (da, db) = chunk[slot.min(chunk.len() - 1)];
                x1[slot * l * d..(slot + 1) * l * d].copy_from_slice(&da.x);
                w1[slot * l..(slot + 1) * l].copy_from_slice(&da.w);
                x2[slot * l * d..(slot + 1) * l * d].copy_from_slice(&db.x);
                w2[slot * l..(slot + 1) * l].copy_from_slice(&db.w);
            }
            let gamma = [self.gamma];
            let vals = self
                .rt
                .lock()
                .unwrap()
                .execute("wmd_sim", &[&x1, &w1, &x2, &w2, &gamma])
                .expect("wmd_sim execution failed");
            out.extend(vals[..chunk.len()].iter().map(|&v| v as f64));
        }
        out
    }
}

impl SimOracle for WmdPjrtOracle {
    fn n(&self) -> usize {
        self.docs.len()
    }

    fn eval_batch(&self, pairs: &[(usize, usize)]) -> Vec<f64> {
        let doc_pairs: Vec<(&PaddedDoc, &PaddedDoc)> = pairs
            .iter()
            .map(|&(i, j)| (&self.docs[i], &self.docs[j]))
            .collect();
        self.run_doc_pairs(&doc_pairs)
    }
}

/// Cross-encoder sentence-pair oracle via the `cross_encoder` artifact.
/// Inherently asymmetric — wrap in [`crate::sim::Symmetrized`] before
/// approximating (Sec. 4.2).
pub struct CrossEncoderPjrtOracle {
    rt: SharedRuntime,
    /// Sentence token embeddings, each seq*dim f32.
    pub sentences: Vec<Vec<f32>>,
    batch: usize,
    seq: usize,
    dim: usize,
}

impl CrossEncoderPjrtOracle {
    pub fn new(rt: SharedRuntime, sentences: Vec<Vec<f32>>) -> Result<CrossEncoderPjrtOracle> {
        let (batch, seq, dim) = {
            let r = rt.lock().unwrap();
            let s = r.manifest.cross_encoder;
            (s.batch, s.seq, s.dim)
        };
        for s in &sentences {
            assert_eq!(s.len(), seq * dim, "sentence shape mismatch");
        }
        Ok(CrossEncoderPjrtOracle {
            rt,
            sentences,
            batch,
            seq,
            dim,
        })
    }
}

impl SimOracle for CrossEncoderPjrtOracle {
    fn n(&self) -> usize {
        self.sentences.len()
    }

    fn eval_batch(&self, pairs: &[(usize, usize)]) -> Vec<f64> {
        let (b, sd) = (self.batch, self.seq * self.dim);
        let mut out = Vec::with_capacity(pairs.len());
        for chunk in pairs.chunks(b) {
            let mut x1 = vec![0.0f32; b * sd];
            let mut x2 = vec![0.0f32; b * sd];
            for slot in 0..b {
                let (i, j) = chunk[slot.min(chunk.len() - 1)];
                x1[slot * sd..(slot + 1) * sd].copy_from_slice(&self.sentences[i]);
                x2[slot * sd..(slot + 1) * sd].copy_from_slice(&self.sentences[j]);
            }
            let vals = self
                .rt
                .lock()
                .unwrap()
                .execute("cross_encoder", &[&x1, &x2])
                .expect("cross_encoder execution failed");
            out.extend(vals[..chunk.len()].iter().map(|&v| v as f64));
        }
        out
    }
}

/// Coreference mention-pair oracle via the `coref_mlp` artifact.
pub struct CorefPjrtOracle {
    rt: SharedRuntime,
    /// Mention embeddings, each dim f32.
    pub mentions: Vec<Vec<f32>>,
    batch: usize,
    dim: usize,
}

impl CorefPjrtOracle {
    pub fn new(rt: SharedRuntime, mentions: Vec<Vec<f32>>) -> Result<CorefPjrtOracle> {
        let (batch, dim) = {
            let r = rt.lock().unwrap();
            (r.manifest.coref.batch, r.manifest.coref.dim)
        };
        for m in &mentions {
            assert_eq!(m.len(), dim, "mention dim mismatch");
        }
        Ok(CorefPjrtOracle {
            rt,
            mentions,
            batch,
            dim,
        })
    }
}

impl SimOracle for CorefPjrtOracle {
    fn n(&self) -> usize {
        self.mentions.len()
    }

    fn eval_batch(&self, pairs: &[(usize, usize)]) -> Vec<f64> {
        let (b, d) = (self.batch, self.dim);
        let mut out = Vec::with_capacity(pairs.len());
        for chunk in pairs.chunks(b) {
            let mut m1 = vec![0.0f32; b * d];
            let mut m2 = vec![0.0f32; b * d];
            for slot in 0..b {
                let (i, j) = chunk[slot.min(chunk.len() - 1)];
                m1[slot * d..(slot + 1) * d].copy_from_slice(&self.mentions[i]);
                m2[slot * d..(slot + 1) * d].copy_from_slice(&self.mentions[j]);
            }
            let vals = self
                .rt
                .lock()
                .unwrap()
                .execute("coref_mlp", &[&m1, &m2])
                .expect("coref_mlp execution failed");
            out.extend(vals[..chunk.len()].iter().map(|&v| v as f64));
        }
        out
    }
}
