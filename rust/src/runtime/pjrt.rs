//! PJRT runtime: load AOT artifacts (HLO text) and execute them on the CPU
//! client. Mirrors /opt/xla-example/load_hlo — text interchange because
//! xla_extension 0.5.1 rejects jax≥0.5 serialized protos.
//!
//! The client and executables are owned by one `Runtime`; `SimOracle`
//! implementations wrap it in a `Mutex` (PJRT handles are not `Sync`), and
//! the coordinator runs executions on a dedicated worker thread.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::manifest::{ArtifactSpec, Manifest};

pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Executions performed per artifact (serving metrics).
    pub exec_counts: HashMap<String, u64>,
}

// SAFETY: the xla crate wraps PJRT handles in `Rc`, making them !Send, but
// every Rc clone lives inside this Runtime (client + executables compiled
// from it) and is never shared outside it. All access goes through
// `Arc<Mutex<Runtime>>` (see oracles.rs), so at most one thread touches the
// handles — and the PJRT CPU client itself is thread-safe. Moving the whole
// Runtime between threads under those conditions is sound.
unsafe impl Send for Runtime {}

impl Runtime {
    /// Load + compile every artifact in the manifest (eager: serve-time
    /// latency must not include compilation).
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let mut exes = HashMap::new();
        for (name, spec) in &manifest.artifacts {
            let exe = compile_artifact(&client, spec)
                .with_context(|| format!("compiling artifact '{name}'"))?;
            exes.insert(name.clone(), exe);
        }
        Ok(Runtime {
            manifest,
            client,
            exes,
            exec_counts: HashMap::new(),
        })
    }

    /// Load only the named artifacts (tests that need a subset compile
    /// faster).
    pub fn load_subset(dir: impl AsRef<Path>, names: &[&str]) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let mut exes = HashMap::new();
        for &name in names {
            let spec = manifest.spec(name)?;
            let exe = compile_artifact(&client, spec)
                .with_context(|| format!("compiling artifact '{name}'"))?;
            exes.insert(name.to_string(), exe);
        }
        Ok(Runtime {
            manifest,
            client,
            exes,
            exec_counts: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute an artifact with f32 inputs, shape-checked against the
    /// manifest. Returns the flattened f32 output.
    pub fn execute(&mut self, name: &str, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        let spec = self.manifest.spec(name)?.clone();
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not loaded"))?;
        if inputs.len() != spec.inputs.len() {
            return Err(anyhow!(
                "artifact '{name}': {} inputs supplied, {} expected",
                inputs.len(),
                spec.inputs.len()
            ));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (k, (data, shape)) in inputs.iter().zip(&spec.inputs).enumerate() {
            let numel: usize = shape.iter().product();
            if data.len() != numel {
                return Err(anyhow!(
                    "artifact '{name}' input {k}: {} elements, shape {:?} needs {numel}",
                    data.len(),
                    shape
                ));
            }
            let lit = if shape.is_empty() {
                xla::Literal::from(data[0])
            } else {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshape input {k}: {e:?}"))?
            };
            literals.push(lit);
        }
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute '{name}': {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal '{name}': {e:?}"))?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow!("untuple '{name}': {e:?}"))?;
        let values = out
            .to_vec::<f32>()
            .map_err(|e| anyhow!("read output '{name}': {e:?}"))?;
        let expect: usize = spec.output.iter().product();
        if values.len() != expect {
            return Err(anyhow!(
                "artifact '{name}': output {} elements, expected {expect}",
                values.len()
            ));
        }
        *self.exec_counts.entry(name.to_string()).or_insert(0) += 1;
        Ok(values)
    }
}

fn compile_artifact(
    client: &xla::PjRtClient,
    spec: &ArtifactSpec,
) -> Result<xla::PjRtLoadedExecutable> {
    let path = spec
        .file
        .to_str()
        .ok_or_else(|| anyhow!("non-utf8 path {:?}", spec.file))?;
    let proto = xla::HloModuleProto::from_text_file(path)
        .map_err(|e| anyhow!("parse HLO text {path}: {e:?}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow!("compile {path}: {e:?}"))
}
