//! Bayesian optimization (GP surrogate + Expected Improvement) over a
//! bounded box — the hyperparameter search (γ, λ⁻¹, s₂) of Appendix A /
//! Fig. 5-6. Deterministic given the seed.

use super::gp::Gp;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct BayesOpt {
    pub lo: Vec<f64>,
    pub hi: Vec<f64>,
    pub xs: Vec<Vec<f64>>, // normalized to [0,1]^d
    pub ys: Vec<f64>,
}

/// Standard normal pdf/cdf for EI.
fn phi(x: f64) -> f64 {
    (-0.5 * x * x).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

fn cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// Abramowitz-Stegun erf approximation (|err| < 1.5e-7).
fn erf(x: f64) -> f64 {
    let sign = x.signum();
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let poly = t
        * (0.254829592
            + t * (-0.284496736 + t * (1.421413741 + t * (-1.453152027 + t * 1.061405429))));
    sign * (1.0 - poly * (-x * x).exp())
}

impl BayesOpt {
    pub fn new(lo: Vec<f64>, hi: Vec<f64>) -> BayesOpt {
        assert_eq!(lo.len(), hi.len());
        BayesOpt {
            lo,
            hi,
            xs: Vec::new(),
            ys: Vec::new(),
        }
    }

    fn dim(&self) -> usize {
        self.lo.len()
    }

    fn denorm(&self, x: &[f64]) -> Vec<f64> {
        x.iter()
            .zip(self.lo.iter().zip(&self.hi))
            .map(|(v, (l, h))| l + v * (h - l))
            .collect()
    }

    pub fn observe(&mut self, x_raw: &[f64], y: f64) {
        let x: Vec<f64> = x_raw
            .iter()
            .zip(self.lo.iter().zip(&self.hi))
            .map(|(v, (l, h))| ((v - l) / (h - l)).clamp(0.0, 1.0))
            .collect();
        self.xs.push(x);
        self.ys.push(y);
    }

    /// Next point to evaluate (maximization): random for the first few,
    /// then EI maximized over random candidates.
    pub fn suggest(&self, rng: &mut Rng) -> Vec<f64> {
        let d = self.dim();
        if self.xs.len() < 2 * d + 1 {
            return self.denorm(&(0..d).map(|_| rng.f64()).collect::<Vec<_>>());
        }
        // Normalize y for GP stability.
        let ymean = self.ys.iter().sum::<f64>() / self.ys.len() as f64;
        let ystd = (self
            .ys
            .iter()
            .map(|y| (y - ymean).powi(2))
            .sum::<f64>()
            / self.ys.len() as f64)
            .sqrt()
            .max(1e-9);
        let yn: Vec<f64> = self.ys.iter().map(|y| (y - ymean) / ystd).collect();
        let Ok(gp) = Gp::fit(self.xs.clone(), &yn, 0.25, 1.0, 0.05) else {
            return self.denorm(&(0..d).map(|_| rng.f64()).collect::<Vec<_>>());
        };
        let best = yn.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut top = (f64::NEG_INFINITY, vec![0.5; d]);
        for _ in 0..256 {
            let cand: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
            let (m, v) = gp.predict(&cand);
            let s = v.sqrt();
            let zscore = (m - best) / s;
            let ei = (m - best) * cdf(zscore) + s * phi(zscore);
            if ei > top.0 {
                top = (ei, cand);
            }
        }
        self.denorm(&top.1)
    }

    pub fn best(&self) -> Option<(Vec<f64>, f64)> {
        let i = self
            .ys
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())?
            .0;
        Some((self.denorm(&self.xs[i]), self.ys[i]))
    }
}

/// Run a full BO loop against an objective.
pub fn maximize(
    lo: Vec<f64>,
    hi: Vec<f64>,
    budget: usize,
    rng: &mut Rng,
    mut f: impl FnMut(&[f64]) -> f64,
) -> (Vec<f64>, f64, BayesOpt) {
    let mut bo = BayesOpt::new(lo, hi);
    for _ in 0..budget {
        let x = bo.suggest(rng);
        let y = f(&x);
        bo.observe(&x, y);
    }
    let (x, y) = bo.best().unwrap();
    (x, y, bo)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finds_quadratic_peak() {
        let mut rng = Rng::new(1);
        let (x, y, _) = maximize(
            vec![0.0, 0.0],
            vec![1.0, 1.0],
            40,
            &mut rng,
            |v| -((v[0] - 0.7).powi(2) + (v[1] - 0.3).powi(2)),
        );
        assert!(y > -0.02, "best objective {y}");
        assert!((x[0] - 0.7).abs() < 0.15 && (x[1] - 0.3).abs() < 0.15, "{x:?}");
    }

    #[test]
    fn beats_pure_random_on_narrow_peak() {
        let obj = |v: &[f64]| -(10.0 * (v[0] - 0.42)).powi(2);
        let mut rng = Rng::new(2);
        let (_, y_bo, _) = maximize(vec![0.0], vec![1.0], 30, &mut rng, obj);
        let mut rng2 = Rng::new(2);
        let y_rand = (0..30)
            .map(|_| obj(&[rng2.f64()]))
            .fold(f64::NEG_INFINITY, f64::max);
        assert!(y_bo >= y_rand - 1e-9, "bo {y_bo} vs random {y_rand}");
    }

    #[test]
    fn erf_sane() {
        assert!((erf(0.0)).abs() < 1e-6);
        assert!((erf(10.0) - 1.0).abs() < 1e-7);
        assert!((cdf(0.0) - 0.5).abs() < 1e-7);
    }
}
