//! Bayesian hyperparameter optimization substrate (GP + EI) — used by the
//! Fig. 5/6 validation-accuracy sweeps.

pub mod bayes;
pub mod gp;

pub use bayes::{maximize, BayesOpt};
pub use gp::Gp;
