//! Gaussian-process regression (RBF kernel + observation noise) — the
//! surrogate model behind the Bayesian hyperparameter optimizer used for
//! the Fig. 5/6 rank/γ sweeps (Shahriari et al. 2015 substitute).

use crate::linalg::cholesky::{chol_solve, cholesky};
use crate::linalg::Mat;

pub struct Gp {
    x: Vec<Vec<f64>>,
    alpha: Vec<f64>,
    chol: Mat,
    pub lengthscale: f64,
    pub signal: f64,
    pub noise: f64,
}

fn rbf(a: &[f64], b: &[f64], ls: f64, sig: f64) -> f64 {
    let d2: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    sig * sig * (-d2 / (2.0 * ls * ls)).exp()
}

impl Gp {
    /// Fit on observations (x_i, y_i). Inputs should be normalized to
    /// [0, 1]^d by the caller.
    pub fn fit(
        x: Vec<Vec<f64>>,
        y: &[f64],
        lengthscale: f64,
        signal: f64,
        noise: f64,
    ) -> Result<Gp, String> {
        let n = x.len();
        assert_eq!(n, y.len());
        let mut k = Mat::from_fn(n, n, |i, j| rbf(&x[i], &x[j], lengthscale, signal));
        k.shift_diag(noise * noise + 1e-10);
        let chol = cholesky(&k)?;
        let alpha = chol_solve(&chol, y);
        Ok(Gp {
            x,
            alpha,
            chol,
            lengthscale,
            signal,
            noise,
        })
    }

    /// Predictive mean and variance at a point.
    pub fn predict(&self, xq: &[f64]) -> (f64, f64) {
        let n = self.x.len();
        let kq: Vec<f64> = (0..n)
            .map(|i| rbf(&self.x[i], xq, self.lengthscale, self.signal))
            .collect();
        let mean: f64 = kq.iter().zip(&self.alpha).map(|(k, a)| k * a).sum();
        let v = chol_solve(&self.chol, &kq);
        let var = self.signal * self.signal - kq.iter().zip(&v).map(|(k, w)| k * w).sum::<f64>();
        (mean, var.max(1e-12))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interpolates_training_points() {
        let x: Vec<Vec<f64>> = (0..6).map(|i| vec![i as f64 / 5.0]).collect();
        let y: Vec<f64> = x.iter().map(|v| (4.0 * v[0]).sin()).collect();
        let gp = Gp::fit(x.clone(), &y, 0.3, 1.0, 1e-3).unwrap();
        for (xi, yi) in x.iter().zip(&y) {
            let (m, v) = gp.predict(xi);
            assert!((m - yi).abs() < 0.05, "mean {m} vs {yi}");
            assert!(v < 0.05);
        }
    }

    #[test]
    fn uncertainty_grows_away_from_data() {
        let x = vec![vec![0.0], vec![0.1]];
        let y = vec![0.0, 0.1];
        let gp = Gp::fit(x, &y, 0.15, 1.0, 1e-3).unwrap();
        let (_, v_near) = gp.predict(&[0.05]);
        let (_, v_far) = gp.predict(&[0.9]);
        assert!(v_far > v_near * 5.0);
    }
}
